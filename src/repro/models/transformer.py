"""Unified decoder-only LM covering dense / MoE / VLM / SSM / hybrid.

One parameter schema + three entry points per architecture family:

* ``init(key, cfg)``          — parameters (jit-traceable, eval_shape-safe)
* ``loss_fn(params, batch)``  — next-token NLL (training / train_4k cells)
* ``prefill_logits`` / ``init_cache`` / ``decode_step`` — serving cells

Layers are **stacked** (leading ``L`` dim on every leaf) and iterated
with ``lax.scan`` so the lowered HLO is layer-count-independent —
compile times for the 94-layer 235B config match the 16-layer 1B one.
Heterogeneous stacks (RecurrentGemma triads) scan over repeating groups
plus an unscanned tail.

The KV cache is a pytree of stacked buffers:
  full attention: ``k/v [L, B, Hkv, S_max, Dh]`` (absolute slots)
  sliding window: ``k/v [L, B, Hkv, window, Dh]`` (ring buffer)
  ssm/rec:        per-block states (O(1) in sequence length)
so 500k-context decode on SSM/hybrid architectures is memory-flat.
"""

from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

from . import hybrid, moe, ssm
from .attention import attention
from .common import ArchConfig, dtype_of, shard
from .layers import (apply_norm, chunked_softmax_xent, embed, embedding_init,
                     mlp_apply, mlp_init, norm_init, apply_rope)

NEG_INF = -1e30


# ---------------------------------------------------------------------------
# Per-block init/apply
# ---------------------------------------------------------------------------

def attn_init(key, cfg: ArchConfig, dtype):
    d, h, hkv, dh = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.d_head
    ks = jax.random.split(key, 4)
    s = 1.0 / np.sqrt(d)
    so = 1.0 / np.sqrt(h * dh)
    p = {
        "wq": jax.random.normal(ks[0], (d, h * dh), dtype) * s,
        "wk": jax.random.normal(ks[1], (d, hkv * dh), dtype) * s,
        "wv": jax.random.normal(ks[2], (d, hkv * dh), dtype) * s,
        "wo": jax.random.normal(ks[3], (h * dh, d), dtype) * so,
    }
    if cfg.qkv_bias:
        p["bq"] = jnp.zeros((h * dh,), dtype)
        p["bk"] = jnp.zeros((hkv * dh,), dtype)
        p["bv"] = jnp.zeros((hkv * dh,), dtype)
    return p


def _project_qkv(p, x, cfg: ArchConfig, positions):
    b, s, _ = x.shape
    cd = x.dtype
    h, hkv, dh = cfg.n_heads, cfg.n_kv_heads, cfg.d_head
    q = x @ p["wq"].astype(cd)
    k = x @ p["wk"].astype(cd)
    v = x @ p["wv"].astype(cd)
    if cfg.qkv_bias:
        q = q + p["bq"].astype(cd)
        k = k + p["bk"].astype(cd)
        v = v + p["bv"].astype(cd)
    q = q.reshape(b, s, h, dh).transpose(0, 2, 1, 3)
    k = k.reshape(b, s, hkv, dh).transpose(0, 2, 1, 3)
    v = v.reshape(b, s, hkv, dh).transpose(0, 2, 1, 3)
    q = shard(q, "batch", "heads", None, None)
    k = shard(k, "batch", "kv_heads", None, None)
    v = shard(v, "batch", "kv_heads", None, None)
    if cfg.rope in ("rope", "mrope"):
        q = apply_rope(q, positions, cfg.rope_theta)
        k = apply_rope(k, positions, cfg.rope_theta)
    return q, k, v


def attn_apply(p, x, cfg: ArchConfig, positions, impl: str = "auto",
               causal: bool = True):
    b, s, _ = x.shape
    q, k, v = _project_qkv(p, x, cfg, positions)
    o = attention(q, k, v, cfg, causal=causal, impl=impl)
    o = shard(o, "batch", "heads", None, None)
    o = o.transpose(0, 2, 1, 3).reshape(b, s, cfg.n_heads * cfg.d_head)
    return o @ p["wo"].astype(x.dtype)


def block_init(key, cfg: ArchConfig, dtype, kind: str):
    """kind: attn | moe_attn | ssm | rec"""
    k1, k2, k3 = jax.random.split(key, 3)
    if kind == "ssm":
        return {"ln": norm_init(cfg, dtype),
                "ssm": ssm.ssm_block_init(k1, cfg, dtype)}
    if kind == "rec":
        return {"ln": norm_init(cfg, dtype),
                "rec": hybrid.rec_block_init(k1, cfg, dtype),
                "ln2": norm_init(cfg, dtype),
                "mlp": mlp_init(k2, cfg, dtype)}
    p = {"ln1": norm_init(cfg, dtype),
         "attn": attn_init(k1, cfg, dtype),
         "ln2": norm_init(cfg, dtype)}
    if kind == "moe_attn":
        p["moe"] = moe.moe_init(k2, cfg, dtype)
    else:
        p["mlp"] = mlp_init(k2, cfg, dtype)
    return p


def block_apply(p, x, cfg: ArchConfig, positions, kind: str,
                impl: str = "auto"):
    if kind == "ssm":
        return x + ssm.ssm_block_apply(
            {k: v for k, v in p["ssm"].items()},
            apply_norm(cfg, p["ln"], x), cfg)
    if kind == "rec":
        h = x + hybrid.rec_block_apply(p["rec"],
                                       apply_norm(cfg, p["ln"], x), cfg)
        return h + mlp_apply(p["mlp"], apply_norm(cfg, p["ln2"], h), cfg)
    h = x + attn_apply(p["attn"], apply_norm(cfg, p["ln1"], x), cfg,
                       positions, impl=impl)
    inner = apply_norm(cfg, p["ln2"], h)
    if kind == "moe_attn":
        return h + moe.moe_apply(p["moe"], inner, cfg)
    return h + mlp_apply(p["mlp"], inner, cfg)


# ---------------------------------------------------------------------------
# Layer-stack plan (which kinds, how scanned)
# ---------------------------------------------------------------------------

def stack_plan(cfg: ArchConfig) -> tuple[tuple[str, ...], int, tuple[str, ...]]:
    """Returns (group_kinds, n_groups, tail_kinds)."""
    if cfg.family == "ssm":
        return ("ssm",), cfg.n_layers, ()
    if cfg.family == "hybrid":
        pattern = cfg.block_pattern or ("rec", "rec", "attn")
        per = len(pattern)
        n_groups = (cfg.n_layers - cfg.n_tail_layers) // per
        tail = tuple(["rec"] * cfg.n_tail_layers)
        return tuple(pattern), n_groups, tail
    kind = "moe_attn" if cfg.n_experts else "attn"
    return (kind,), cfg.n_layers, ()


# ---------------------------------------------------------------------------
# Model init
# ---------------------------------------------------------------------------

def init(key, cfg: ArchConfig):
    dtype = dtype_of(cfg, "param_dtype")
    group_kinds, n_groups, tail_kinds = stack_plan(cfg)
    k_emb, k_layers, k_tail, k_head = jax.random.split(key, 4)

    def group_init(k):
        ks = jax.random.split(k, len(group_kinds))
        return {f"b{i}_{kind}": block_init(ks[i], cfg, dtype, kind)
                for i, kind in enumerate(group_kinds)}

    layer_keys = jax.random.split(k_layers, n_groups)
    layers = jax.vmap(group_init)(layer_keys)

    params: dict[str, Any] = {
        "embed": embedding_init(k_emb, cfg.vocab, cfg.d_model, dtype),
        "layers": layers,
        "final_norm": norm_init(cfg, dtype),
    }
    if tail_kinds:
        tkeys = jax.random.split(k_tail, len(tail_kinds))
        params["tail"] = [block_init(tk, cfg, dtype, kind)
                          for tk, kind in zip(tkeys, tail_kinds)]
    if not cfg.tie_embeddings:
        params["lm_head"] = {
            "w": jax.random.normal(k_head, (cfg.d_model, cfg.vocab), dtype)
            * (1.0 / np.sqrt(cfg.d_model))}
    return params


def abstract_params(cfg: ArchConfig, seed: int = 0):
    """Parameter ShapeDtypeStructs without allocating (dry-run path)."""
    return jax.eval_shape(lambda: init(jax.random.PRNGKey(seed), cfg))


# ---------------------------------------------------------------------------
# Forward (full-sequence) + loss
# ---------------------------------------------------------------------------

def _run_stack(params, x, cfg: ArchConfig, positions, impl: str,
               layer_transform=None):
    """``layer_transform(group_params, group_index) -> group_params`` lets
    the trainer interpose per-layer parameter movement (e.g. the
    MPC-FSDP all-gather whose backward is a secure reduce-scatter)."""
    group_kinds, n_groups, tail_kinds = stack_plan(cfg)

    def group_body(xc, inputs):
        gp, gidx = inputs
        if layer_transform is not None:
            gp = layer_transform(gp, gidx)
        for i, kind in enumerate(group_kinds):
            xc = block_apply(gp[f"b{i}_{kind}"], xc, cfg, positions, kind,
                             impl=impl)
        return xc, None

    if cfg.remat:
        policy = (jax.checkpoint_policies.dots_with_no_batch_dims_saveable
                  if cfg.remat_policy == "dots" else None)
        body = jax.checkpoint(group_body, policy=policy)
    else:
        body = group_body
    x, _ = jax.lax.scan(body, x,
                        (params["layers"],
                         jnp.arange(n_groups, dtype=jnp.int32)))
    for t_i, (tp, kind) in enumerate(zip(params.get("tail", []),
                                         tail_kinds)):
        if layer_transform is not None:
            tp = layer_transform(tp, jnp.int32(n_groups + t_i))
        x = block_apply(tp, x, cfg, positions, kind, impl=impl)
    return apply_norm(cfg, params["final_norm"], x)


def forward_hidden(params, batch, cfg: ArchConfig, impl: str = "auto",
                   layer_transform=None):
    cd = dtype_of(cfg, "compute_dtype")
    if cfg.frontend == "embeddings":
        x = batch["embeds"].astype(cd)
    else:
        x = embed(params["embed"], batch["tokens"], cd)
    b, s, _ = x.shape
    x = shard(x, "batch", "seq", "embed")
    positions = batch.get("positions")
    if positions is None:
        positions = jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32), (b, s))
    return _run_stack(params, x, cfg, positions, impl,
                      layer_transform=layer_transform)


def lm_head_weight(params, cfg: ArchConfig):
    if cfg.tie_embeddings:
        return params["embed"]["table"].T
    return params["lm_head"]["w"]


def loss_fn(params, batch, cfg: ArchConfig, impl: str = "auto",
            layer_transform=None):
    """Mean next-token NLL.  batch: tokens/embeds [B,S], labels [B,S]."""
    h = forward_hidden(params, batch, cfg, impl=impl,
                       layer_transform=layer_transform)
    w = lm_head_weight(params, cfg)
    return chunked_softmax_xent(h, w, batch["labels"],
                                label_mask=batch.get("label_mask"))


def logits_fn(params, batch, cfg: ArchConfig, impl: str = "auto"):
    """Full logits (only for smoke-scale tests/examples)."""
    h = forward_hidden(params, batch, cfg, impl=impl)
    w = lm_head_weight(params, cfg)
    return (h @ w.astype(h.dtype)).astype(jnp.float32)


# ---------------------------------------------------------------------------
# KV cache + decode
# ---------------------------------------------------------------------------

def init_cache(cfg: ArchConfig, batch: int, kv_len: int,
               dtype=jnp.bfloat16):
    """Stacked decode state for every layer group."""
    group_kinds, n_groups, tail_kinds = stack_plan(cfg)
    hkv, dh = cfg.n_kv_heads, cfg.d_head

    def one(kind, n):
        if kind == "ssm":
            st = ssm.ssm_init_state(cfg, batch)
            return jax.tree.map(lambda a: jnp.zeros((n,) + a.shape, a.dtype),
                                st)
        if kind == "rec":
            st = hybrid.rec_init_state(cfg, batch)
            return jax.tree.map(lambda a: jnp.zeros((n,) + a.shape, a.dtype),
                                st)
        s_buf = min(kv_len, cfg.window) if cfg.window else kv_len
        return {
            "k": jnp.zeros((n, batch, hkv, s_buf, dh), dtype),
            "v": jnp.zeros((n, batch, hkv, s_buf, dh), dtype),
        }

    cache = {"groups": {f"b{i}_{kind}": one(kind, n_groups)
                        for i, kind in enumerate(group_kinds)}}
    if tail_kinds:
        cache["tail"] = [one(kind, 1) for kind in tail_kinds]
    return cache


def _decode_attn_block(p, x, cache_kv, cfg: ArchConfig, index):
    """One-token attention against a (ring-)buffered KV cache.

    x: [B, d]; cache_kv: {k,v: [B,Hkv,S_buf,Dh]}; index: scalar int32.
    """
    b = x.shape[0]
    cd = x.dtype
    h, hkv, dh = cfg.n_heads, cfg.n_kv_heads, cfg.d_head
    pos = jnp.broadcast_to(index[None, None], (b, 1)).astype(jnp.int32)
    q, k_new, v_new = _project_qkv(p, x[:, None, :], cfg, pos)

    s_buf = cache_kv["k"].shape[2]
    slot = (index % s_buf).astype(jnp.int32)
    k_cache = jax.lax.dynamic_update_slice(
        cache_kv["k"], k_new.astype(cache_kv["k"].dtype),
        (0, 0, slot, 0))
    v_cache = jax.lax.dynamic_update_slice(
        cache_kv["v"], v_new.astype(cache_kv["v"].dtype),
        (0, 0, slot, 0))
    k_cache = shard(k_cache, "batch", "kv_heads", "kv_seq", None)
    v_cache = shard(v_cache, "batch", "kv_heads", "kv_seq", None)

    # slot j holds absolute position p_j = index - ((index - j) mod s_buf)
    j = jnp.arange(s_buf, dtype=jnp.int32)
    abs_pos = index - ((index - j) % s_buf)
    valid = abs_pos >= 0
    if cfg.window:
        valid = valid & (abs_pos > index - cfg.window)

    kk = jnp.repeat(k_cache, h // hkv, axis=1).astype(jnp.float32)
    vv = jnp.repeat(v_cache, h // hkv, axis=1).astype(jnp.float32)
    qf = q[:, :, 0, :].astype(jnp.float32)                  # [B,H,Dh]
    scores = jnp.einsum("bhd,bhsd->bhs", qf, kk) / np.sqrt(dh)
    scores = jnp.where(valid[None, None, :], scores, NEG_INF)
    p_attn = jax.nn.softmax(scores, axis=-1)
    o = jnp.einsum("bhs,bhsd->bhd", p_attn, vv).astype(cd)
    o = o.reshape(b, h * dh)
    out = o @ p["wo"].astype(cd)
    return out, {"k": k_cache, "v": v_cache}


def _decode_block(p, x, st, cfg: ArchConfig, index, kind: str):
    if kind == "ssm":
        y, st2 = ssm.ssm_block_step(p["ssm"],
                                    apply_norm(cfg, p["ln"], x), st, cfg)
        return x + y, st2
    if kind == "rec":
        y, st2 = hybrid.rec_block_step(p["rec"],
                                       apply_norm(cfg, p["ln"], x), st, cfg)
        h = x + y
        h = h + mlp_apply(p["mlp"],
                          apply_norm(cfg, p["ln2"], h[:, None, :]),
                          cfg)[:, 0]
        return h, st2
    y, st2 = _decode_attn_block(p["attn"], apply_norm(cfg, p["ln1"], x),
                                st, cfg, index)
    h = x + y
    inner = apply_norm(cfg, p["ln2"], h[:, None, :])
    if kind == "moe_attn":
        h = h + moe.moe_apply(p["moe"], inner, cfg)[:, 0]
    else:
        h = h + mlp_apply(p["mlp"], inner, cfg)[:, 0]
    return h, st2


def decode_step(params, cache, batch, cfg: ArchConfig):
    """One decode step.  batch: tokens [B,1] (or embeds [B,1,d]),
    index: scalar int32 (current absolute position).

    Returns (logits [B, V], new cache).
    """
    cd = dtype_of(cfg, "compute_dtype")
    index = batch["index"].astype(jnp.int32)
    if cfg.frontend == "embeddings":
        x = batch["embeds"][:, 0, :].astype(cd)
    else:
        x = embed(params["embed"], batch["tokens"][:, 0], cd)
    group_kinds, _, tail_kinds = stack_plan(cfg)

    def group_body(xc, inputs):
        gp, gc = inputs
        new_c = {}
        for i, kind in enumerate(group_kinds):
            name = f"b{i}_{kind}"
            xc, new_c[name] = _decode_block(gp[name], xc, gc[name], cfg,
                                            index, kind)
        return xc, new_c

    x, new_groups = jax.lax.scan(group_body, x,
                                 (params["layers"], cache["groups"]))
    new_cache = {"groups": new_groups}
    if tail_kinds:
        new_tail = []
        for tp, tc, kind in zip(params["tail"], cache["tail"], tail_kinds):
            tc0 = jax.tree.map(lambda a: a[0], tc)
            x, tc2 = _decode_block(tp, x, tc0, cfg, index, kind)
            new_tail.append(jax.tree.map(lambda a: a[None], tc2))
        new_cache["tail"] = new_tail
    x = apply_norm(cfg, params["final_norm"], x[:, None, :])[:, 0]
    w = lm_head_weight(params, cfg)
    logits = (x @ w.astype(x.dtype)).astype(jnp.float32)
    logits = shard(logits, "batch", "vocab")
    return logits, new_cache


def prefill(params, batch, cfg: ArchConfig, impl: str = "auto"):
    """Prefill forward returning last-position logits (inference-prefill
    cells lower this).  Full-cache construction is exercised separately
    by decode cells; prefill measures the compute-bound encode."""
    h = forward_hidden(params, batch, cfg, impl=impl)
    w = lm_head_weight(params, cfg)
    last = h[:, -1, :]
    return (last @ w.astype(last.dtype)).astype(jnp.float32)
