"""Model API registry: family -> (init, loss, prefill, cache, decode)."""

from __future__ import annotations

import dataclasses
from typing import Callable

from . import encdec, transformer
from .common import ArchConfig


@dataclasses.dataclass(frozen=True)
class ModelApi:
    init: Callable
    loss_fn: Callable
    prefill: Callable
    init_cache: Callable
    decode_step: Callable


def _tf_init_cache(params, cfg, batch, kv_len, **kw):
    del params
    return transformer.init_cache(cfg, batch, kv_len, **kw)


TRANSFORMER_API = ModelApi(
    init=transformer.init,
    loss_fn=transformer.loss_fn,
    prefill=transformer.prefill,
    init_cache=_tf_init_cache,
    decode_step=transformer.decode_step,
)

ENCDEC_API = ModelApi(
    init=encdec.init,
    loss_fn=encdec.loss_fn,
    prefill=encdec.prefill,
    init_cache=encdec.init_cache,
    decode_step=encdec.decode_step,
)


def get_api(cfg: ArchConfig) -> ModelApi:
    return ENCDEC_API if cfg.enc_dec else TRANSFORMER_API
