"""Attention: XLA-native chunked (online-softmax) path + Pallas dispatch.

Three implementations behind one API:

* ``pallas`` — the flash kernel (TPU production path, interpret-tested);
* ``xla_chunked`` — ``lax.scan`` over KV blocks with the same streaming
  softmax recurrence, pure jnp.  This is what the multi-device dry-run
  lowers (Pallas can't target the CPU backend), and it has the *same*
  O(S·block) activation footprint, so 32k-prefill memory analysis is
  honest.  Gradients flow through the scan.
* ``dense`` — materialized scores for tiny smoke shapes.

Decode goes through ``decode_attention`` (KV-blocked, LSE partials) with
an optional sequence-sharded variant the serving layer combines via
``psum`` — see ``repro/launch/serve.py``.
"""

from __future__ import annotations


import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels.flash_attention import flash_attention, attention_ref
from .common import ArchConfig

NEG_INF = -1e30


def xla_chunked_attention(q, k, v, *, causal: bool = True, window: int = 0,
                          sm_scale: float | None = None,
                          block_k: int = 512):
    """Streaming-softmax attention via lax.scan over KV blocks.

    q: [B,H,Sq,D]; k/v: [B,Hkv,Skv,D].  Memory: O(Sq·block_k) per head
    instead of O(Sq·Skv).
    """
    b, hq, sq, d = q.shape
    _, hkv, skv, _ = k.shape
    group = hq // hkv
    scale = sm_scale if sm_scale is not None else 1.0 / np.sqrt(d)
    block_k = min(block_k, skv)
    assert skv % block_k == 0
    nkb = skv // block_k

    qf = q.astype(jnp.float32) * scale
    kf = k.reshape(b, hkv, nkb, block_k, d).swapaxes(0, 2)  # [nkb,Hkv,B,...]
    vf = v.reshape(b, hkv, nkb, block_k, d).swapaxes(0, 2)
    offs = skv - sq if causal else 0
    q_idx = jnp.arange(sq) + offs

    def body(carry, inputs):
        m_prev, l_prev, acc = carry
        kb, kc, vc = inputs                     # [Hkv,B,block,d] ×2
        kc = kc.swapaxes(0, 1).astype(jnp.float32)   # [B,Hkv,block,d]
        vc = vc.swapaxes(0, 1).astype(jnp.float32)
        kk = jnp.repeat(kc, group, axis=1)
        vv = jnp.repeat(vc, group, axis=1)
        s = jnp.einsum("bhqd,bhkd->bhqk", qf, kk)
        k_idx = kb * block_k + jnp.arange(block_k)
        mask = jnp.zeros((sq, block_k), dtype=bool)
        if causal:
            mask = mask | (k_idx[None, :] > q_idx[:, None])
        if window and window > 0:
            mask = mask | (k_idx[None, :] <= q_idx[:, None] - window)
        s = jnp.where(mask[None, None], NEG_INF, s)
        m_cur = jnp.max(s, axis=-1)
        m_new = jnp.maximum(m_prev, m_cur)
        alpha = jnp.exp(m_prev - m_new)
        p = jnp.exp(s - m_new[..., None])
        l_new = l_prev * alpha + jnp.sum(p, axis=-1)
        acc = acc * alpha[..., None] + jnp.einsum("bhqk,bhkd->bhqd", p, vv)
        return (m_new, l_new, acc), None

    init = (jnp.full((b, hq, sq), NEG_INF, jnp.float32),
            jnp.zeros((b, hq, sq), jnp.float32),
            jnp.zeros((b, hq, sq, d), jnp.float32))
    (m, l, acc), _ = jax.lax.scan(
        body, init, (jnp.arange(nkb), kf, vf))
    l = jnp.where(l == 0.0, 1.0, l)
    return (acc / l[..., None]).astype(q.dtype)


def attention(q, k, v, cfg: ArchConfig, *, causal: bool = True,
              impl: str = "auto", block_k: int = 512):
    """Dispatching attention entry point.  q:[B,H,Sq,D] k/v:[B,Hkv,Skv,D]."""
    window = cfg.window
    sq, skv = q.shape[2], k.shape[2]
    if impl == "auto":
        if jax.default_backend() == "tpu" and sq % 128 == 0 and \
                skv % 128 == 0:
            impl = "pallas"
        elif skv >= 1024 and skv % 512 == 0:
            impl = "xla_chunked"
        else:
            impl = "dense"
    if impl == "pallas":
        return flash_attention(q, k, v, causal=causal, window=window)
    if impl == "xla_chunked":
        return xla_chunked_attention(q, k, v, causal=causal, window=window,
                                     block_k=block_k)
    return attention_ref(q, k, v, causal=causal, window=window
                         ).astype(q.dtype)
