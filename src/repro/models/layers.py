"""Building-block layers: norms, rotary embeddings, MLPs, losses.

Pure functions over explicit param dicts (no module framework); all
initializers are jit-traceable so the dry-run can ``jax.eval_shape``
them without allocating.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from .common import ArchConfig, shard


# ---------------------------------------------------------------------------
# Norms
# ---------------------------------------------------------------------------

def rmsnorm_init(d: int, dtype):
    return {"scale": jnp.ones((d,), dtype)}


def rmsnorm(params, x, eps: float = 1e-6):
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    out = xf * jax.lax.rsqrt(var + eps)
    return (out * params["scale"].astype(jnp.float32)).astype(x.dtype)


def layernorm_init(d: int, dtype):
    return {"scale": jnp.ones((d,), dtype), "bias": jnp.zeros((d,), dtype)}


def layernorm(params, x, eps: float = 1e-5):
    xf = x.astype(jnp.float32)
    mu = jnp.mean(xf, axis=-1, keepdims=True)
    var = jnp.var(xf, axis=-1, keepdims=True)
    out = (xf - mu) * jax.lax.rsqrt(var + eps)
    out = out * params["scale"].astype(jnp.float32) + \
        params["bias"].astype(jnp.float32)
    return out.astype(x.dtype)


def norm_init(cfg: ArchConfig, dtype):
    return (rmsnorm_init if cfg.norm == "rmsnorm" else layernorm_init)(
        cfg.d_model, dtype)


def apply_norm(cfg: ArchConfig, params, x):
    if cfg.norm == "rmsnorm":
        return rmsnorm(params, x, cfg.norm_eps)
    return layernorm(params, x, cfg.norm_eps)


# ---------------------------------------------------------------------------
# Rotary position embeddings (standard + M-RoPE stub)
# ---------------------------------------------------------------------------

def rope_freqs(d_head: int, theta: float):
    return 1.0 / (theta ** (np.arange(0, d_head, 2) / d_head))


def apply_rope(x, positions, theta: float = 1e4, mrope_sections=None):
    """x: [B, H, S, D]; positions: [B, S] (or [3, B, S] for M-RoPE).

    M-RoPE (Qwen2-VL): the head dim is split into three sections rotated
    by temporal/height/width position streams.  For text tokens the
    three streams coincide, reducing exactly to standard RoPE — the
    frontend stub supplies equal streams, so we accept ``[B, S]`` and
    broadcast; genuine 3-stream ids also work via ``[3, B, S]``.
    """
    d = x.shape[-1]
    freqs = jnp.asarray(rope_freqs(d, theta), dtype=jnp.float32)  # [D/2]
    if positions.ndim == 2:
        pos3 = jnp.broadcast_to(positions[None], (3,) + positions.shape)
    else:
        pos3 = positions
    if mrope_sections is None:
        angles = pos3[0][:, None, :, None].astype(jnp.float32) * freqs
    else:
        # split the D/2 frequency channels into 3 sections, each driven
        # by its own position stream
        secs = np.cumsum(mrope_sections)[:-1]
        parts = []
        prev = 0
        for i, end in enumerate(list(secs) + [d // 2]):
            parts.append(pos3[i][:, None, :, None].astype(jnp.float32)
                         * freqs[prev:end])
            prev = end
        angles = jnp.concatenate(parts, axis=-1)                  # [B,1,S,D/2]
    cos = jnp.cos(angles)
    sin = jnp.sin(angles)
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos],
                          axis=-1)
    return out.astype(x.dtype)


def sinusoidal_positions(seq: int, d: int):
    pos = np.arange(seq)[:, None]
    dim = np.arange(0, d, 2)[None, :]
    angle = pos / np.power(10000.0, dim / d)
    out = np.zeros((seq, d), np.float32)
    out[:, 0::2] = np.sin(angle)
    out[:, 1::2] = np.cos(angle)
    return jnp.asarray(out)


# ---------------------------------------------------------------------------
# Dense (gated) MLP
# ---------------------------------------------------------------------------

def mlp_init(key, cfg: ArchConfig, dtype, d_ff: int | None = None):
    d, f = cfg.d_model, d_ff or cfg.d_ff
    k1, k2, k3 = jax.random.split(key, 3)
    scale_in = 1.0 / np.sqrt(d)
    scale_out = 1.0 / np.sqrt(f)
    if cfg.act in ("silu", "geglu"):
        return {
            "w_gate": jax.random.normal(k1, (d, f), dtype) * scale_in,
            "w_up": jax.random.normal(k2, (d, f), dtype) * scale_in,
            "w_down": jax.random.normal(k3, (f, d), dtype) * scale_out,
        }
    return {
        "w_up": jax.random.normal(k1, (d, f), dtype) * scale_in,
        "b_up": jnp.zeros((f,), dtype),
        "w_down": jax.random.normal(k2, (f, d), dtype) * scale_out,
        "b_down": jnp.zeros((d,), dtype),
    }


def mlp_apply(params, x, cfg: ArchConfig):
    cd = x.dtype
    if cfg.act in ("silu", "geglu"):
        gate = x @ params["w_gate"].astype(cd)
        up = x @ params["w_up"].astype(cd)
        gate = shard(gate, "batch", "seq", "ff")
        act = jax.nn.silu(gate) if cfg.act == "silu" else jax.nn.gelu(gate)
        return (act * up) @ params["w_down"].astype(cd)
    h = x @ params["w_up"].astype(cd) + params["b_up"].astype(cd)
    h = shard(h, "batch", "seq", "ff")
    h = jax.nn.gelu(h)
    return h @ params["w_down"].astype(cd) + params["b_down"].astype(cd)


# ---------------------------------------------------------------------------
# Embedding + chunked cross-entropy (vocab-sharded friendly)
# ---------------------------------------------------------------------------

def embedding_init(key, vocab: int, d: int, dtype):
    return {"table": jax.random.normal(key, (vocab, d), dtype) * 0.02}


def embed(params, tokens, compute_dtype):
    return params["table"].astype(compute_dtype)[tokens]


def chunked_softmax_xent(x, w_vocab, labels, *, chunk: int = 1024,
                         label_mask=None):
    """Cross-entropy over a large vocab without materializing [B,S,V].

    Scans over sequence chunks; each chunk's logits live only inside the
    scan body (bf16), bounding activation memory at ``B·chunk·V`` —
    *the* enabling trick for vocab≈152k models (20 GB of fp32 logits per
    device otherwise).

    x: [B, S, d] activations; w_vocab: [d, V]; labels: [B, S] int32.
    Returns the mean NLL over unmasked positions.
    """
    b, s, d = x.shape
    chunk = min(chunk, s)
    n_chunks = s // chunk
    assert s % chunk == 0, (s, chunk)
    if label_mask is None:
        label_mask = jnp.ones((b, s), dtype=jnp.float32)

    def body(carry, inputs):
        xc, yc, mc = inputs          # [B, C, d], [B, C], [B, C]
        logits = (xc @ w_vocab.astype(xc.dtype)).astype(jnp.float32)
        logits = shard(logits, "batch", None, "vocab")
        lse = jax.nn.logsumexp(logits, axis=-1)
        gold = jnp.take_along_axis(logits, yc[..., None], axis=-1)[..., 0]
        nll = (lse - gold) * mc
        return (carry[0] + jnp.sum(nll), carry[1] + jnp.sum(mc)), None

    xs = (x.reshape(b, n_chunks, chunk, d).swapaxes(0, 1),
          labels.reshape(b, n_chunks, chunk).swapaxes(0, 1),
          label_mask.reshape(b, n_chunks, chunk).swapaxes(0, 1))
    (total, count), _ = jax.lax.scan(body, (jnp.float32(0), jnp.float32(0)),
                                     xs)
    return total / jnp.maximum(count, 1.0)
