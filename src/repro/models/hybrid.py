"""Griffin / RecurrentGemma blocks: RG-LRU recurrence + local attention.

The 38-layer RecurrentGemma-9B stacks repeating (rec, rec, local-attn)
triads (Griffin's 1-attention-per-3 pattern); the two leftover layers
are recurrent.  The recurrent block is Griffin's dual-branch gated
design: ``merge(GeLU(W_g x) ⊙ RG-LRU(conv1d(W_x x)))``.

RG-LRU (per Griffin Eq. 2-4, c = 8):
    r_t = σ(W_a x_t);  i_t = σ(W_x x_t)
    a_t = exp(−c·softplus(Λ)·r_t)
    h_t = a_t ⊙ h_{t−1} + sqrt(1 − a_t²) ⊙ (i_t ⊙ x_t)

realized with the same chunked associative scan as Mamba.  Local
attention uses the sliding-window path of the flash/chunked kernels
(window 2048), giving O(S·w) prefill and an O(w) KV cache — the reason
this architecture runs the 500k-context cell.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from .common import ArchConfig, shard
from .scan_utils import chunked_linear_scan
from .ssm import _causal_conv

_C = 8.0  # Griffin's fixed recurrence sharpness


def rglru_init(key, width: int, dtype):
    k1, k2, k3 = jax.random.split(key, 3)
    s = 1.0 / np.sqrt(width)
    # Λ init so that a ∈ [0.9, 0.999] at r = 1 (Griffin appendix A)
    lam = jnp.log(jnp.expm1(
        -jnp.log(jnp.linspace(0.9, 0.999, width)) / _C)).astype(jnp.float32)
    return {
        "w_a": jax.random.normal(k1, (width, width), dtype) * s,
        "b_a": jnp.zeros((width,), dtype),
        "w_i": jax.random.normal(k2, (width, width), dtype) * s,
        "b_i": jnp.zeros((width,), dtype),
        "lambda": lam,
    }


def _rglru_gates(params, x):
    cd = x.dtype
    r = jax.nn.sigmoid((x @ params["w_a"].astype(cd)
                        + params["b_a"].astype(cd)).astype(jnp.float32))
    i = jax.nn.sigmoid((x @ params["w_i"].astype(cd)
                        + params["b_i"].astype(cd)).astype(jnp.float32))
    log_a = -_C * jax.nn.softplus(params["lambda"]) * r
    a = jnp.exp(log_a)
    gated = jnp.sqrt(jnp.maximum(1.0 - a * a, 1e-12)) * \
        (i * x.astype(jnp.float32))
    return a, gated


def rglru_apply(params, x, chunk: int = 64, h0=None):
    """x: [B, S, W] -> ([B, S, W], h_last [B, W])."""
    a, b = _rglru_gates(params, x)
    if jax.default_backend() == "tpu" and h0 is None \
            and x.shape[1] % 128 == 0 and x.shape[2] % 128 == 0:
        # fused Pallas path: carry lives in VMEM (kernels/lru_scan)
        from repro.kernels.lru_scan import lru_scan
        hs = lru_scan(a, b)
        return hs.astype(x.dtype), hs[:, -1].astype(jnp.float32)
    hs, h_last = chunked_linear_scan(a, b, h0=h0, chunk=chunk)
    return hs.astype(x.dtype), h_last


def rglru_step(params, x, h):
    """x: [B, W], h: [B, W] -> (y [B, W], h' [B, W])."""
    a, b = _rglru_gates(params, x[:, None, :])
    a = a[:, 0]
    b = b[:, 0]
    h_new = a * h + b
    return h_new.astype(x.dtype), h_new


def rec_block_init(key, cfg: ArchConfig, dtype):
    d = cfg.d_model
    w = cfg.lru_width or d
    ks = jax.random.split(key, 5)
    s = 1.0 / np.sqrt(d)
    sw = 1.0 / np.sqrt(w)
    return {
        "w_gate": jax.random.normal(ks[0], (d, w), dtype) * s,
        "w_x": jax.random.normal(ks[1], (d, w), dtype) * s,
        "conv_w": jax.random.normal(ks[2], (4, w), dtype) * 0.2,
        "conv_b": jnp.zeros((w,), dtype),
        "lru": rglru_init(ks[3], w, dtype),
        "w_out": jax.random.normal(ks[4], (w, d), dtype) * sw,
    }


def rec_block_apply(params, x, cfg: ArchConfig, chunk: int = 64):
    """Griffin recurrent block, full sequence.  x: [B,S,d]."""
    cd = x.dtype
    gate = jax.nn.gelu(x @ params["w_gate"].astype(cd))
    u = x @ params["w_x"].astype(cd)
    u = shard(u, "batch", "seq", "ff")
    u = _causal_conv(u, params["conv_w"].astype(cd),
                     params["conv_b"].astype(cd))
    y, _ = rglru_apply(params["lru"], u, chunk=chunk)
    return (gate * y) @ params["w_out"].astype(cd)


def rec_init_state(cfg: ArchConfig, batch: int, dtype=jnp.float32):
    w = cfg.lru_width or cfg.d_model
    return {
        "conv": jnp.zeros((batch, 3, w), dtype),
        "h": jnp.zeros((batch, w), jnp.float32),
    }


def rec_block_step(params, x, state, cfg: ArchConfig):
    """Single-token decode for the recurrent block.  x: [B, d]."""
    cd = x.dtype
    gate = jax.nn.gelu(x @ params["w_gate"].astype(cd))
    u = x @ params["w_x"].astype(cd)
    conv_buf = jnp.concatenate([state["conv"].astype(cd), u[:, None, :]], 1)
    w = params["conv_w"].astype(cd)
    u_c = jnp.einsum("bkd,kd->bd", conv_buf, w) + params["conv_b"].astype(cd)
    y, h_new = rglru_step(params["lru"], u_c, state["h"])
    out = (gate * y) @ params["w_out"].astype(cd)
    return out, {"conv": conv_buf[:, 1:].astype(state["conv"].dtype),
                 "h": h_new}
