"""Whisper-style encoder-decoder backbone (conv frontend stubbed).

Per the assignment, ``input_specs()`` supplies *post-conv* frame
embeddings ``[B, enc_seq, d]`` — the two strided conv1d layers of the
real Whisper frontend are a stub.  Everything downstream is faithful:
sinusoidal encoder positions, bidirectional encoder self-attention
(MHA; kv = heads for whisper-large-v3), learned decoder positions,
causal decoder self-attention + cross-attention, GELU MLPs, pre-LN
LayerNorm with bias, tied decoder embedding/LM head.

Both stacks are scanned (stacked leaves), like ``transformer.py``.
"""

from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp

from .attention import attention
from .common import ArchConfig, dtype_of, shard
from .layers import (apply_norm, chunked_softmax_xent, embed, embedding_init,
                     mlp_apply, mlp_init,
                     norm_init, sinusoidal_positions)
from .transformer import attn_init, attn_apply, _decode_attn_block

NEG_INF = -1e30


def _enc_block_init(key, cfg: ArchConfig, dtype):
    k1, k2 = jax.random.split(key)
    return {"ln1": norm_init(cfg, dtype), "attn": attn_init(k1, cfg, dtype),
            "ln2": norm_init(cfg, dtype), "mlp": mlp_init(k2, cfg, dtype)}


def _dec_block_init(key, cfg: ArchConfig, dtype):
    k1, k2, k3 = jax.random.split(key, 3)
    return {"ln1": norm_init(cfg, dtype), "self": attn_init(k1, cfg, dtype),
            "ln_x": norm_init(cfg, dtype), "cross": attn_init(k2, cfg, dtype),
            "ln2": norm_init(cfg, dtype), "mlp": mlp_init(k3, cfg, dtype)}


#: learned decoder positions sized for the largest decode cell (32k);
#: whisper's real 448-token table is a special case of the same layout.
MAX_DEC_LEN = 32768 + 8


def init(key, cfg: ArchConfig):
    dtype = dtype_of(cfg, "param_dtype")
    k_emb, k_enc, k_dec, k_pos = jax.random.split(key, 4)
    enc_keys = jax.random.split(k_enc, cfg.n_enc_layers)
    dec_keys = jax.random.split(k_dec, cfg.n_layers)
    return {
        "embed": embedding_init(k_emb, cfg.vocab, cfg.d_model, dtype),
        "dec_pos": jax.random.normal(k_pos, (MAX_DEC_LEN, cfg.d_model),
                                     dtype) * 0.01,
        "enc_layers": jax.vmap(
            lambda k: _enc_block_init(k, cfg, dtype))(enc_keys),
        "enc_norm": norm_init(cfg, dtype),
        "dec_layers": jax.vmap(
            lambda k: _dec_block_init(k, cfg, dtype))(dec_keys),
        "dec_norm": norm_init(cfg, dtype),
    }


def _cross_attn(p, x, enc_kv, cfg: ArchConfig):
    """x: [B,Sq,d] queries; enc_kv: precomputed {k,v: [B,H,Se,Dh]}."""
    b, sq, _ = x.shape
    cd = x.dtype
    h, dh = cfg.n_heads, cfg.d_head
    q = (x @ p["wq"].astype(cd)).reshape(b, sq, h, dh).transpose(0, 2, 1, 3)
    q = shard(q, "batch", "heads", None, None)
    o = attention(q, enc_kv["k"].astype(cd), enc_kv["v"].astype(cd), cfg,
                  causal=False, impl="auto")
    o = o.transpose(0, 2, 1, 3).reshape(b, sq, h * dh)
    return o @ p["wo"].astype(cd)


def cross_kv(p, enc_out, cfg: ArchConfig):
    b, se, _ = enc_out.shape
    cd = enc_out.dtype
    h, dh = cfg.n_heads, cfg.d_head
    k = (enc_out @ p["wk"].astype(cd)).reshape(b, se, h, dh)
    v = (enc_out @ p["wv"].astype(cd)).reshape(b, se, h, dh)
    return {"k": k.transpose(0, 2, 1, 3), "v": v.transpose(0, 2, 1, 3)}


def encode(params, frames, cfg: ArchConfig):
    """frames: [B, Se, d] post-conv embeddings -> encoder states."""
    cd = dtype_of(cfg, "compute_dtype")
    x = frames.astype(cd) + sinusoidal_positions(
        frames.shape[1], cfg.d_model).astype(cd)[None]
    x = shard(x, "batch", "seq", "embed")
    pos = jnp.broadcast_to(jnp.arange(frames.shape[1], dtype=jnp.int32),
                           frames.shape[:2])

    def body(xc, lp):
        h = xc + attn_apply(lp["attn"], apply_norm(cfg, lp["ln1"], xc),
                            cfg, pos, causal=False)
        return h + mlp_apply(lp["mlp"], apply_norm(cfg, lp["ln2"], h),
                             cfg), None

    body = jax.checkpoint(body) if cfg.remat else body
    x, _ = jax.lax.scan(body, x, params["enc_layers"])
    return apply_norm(cfg, params["enc_norm"], x)


def decode_hidden(params, tokens, enc_out, cfg: ArchConfig,
                  positions=None):
    cd = dtype_of(cfg, "compute_dtype")
    b, s = tokens.shape
    x = embed(params["embed"], tokens, cd)
    x = x + params["dec_pos"][:s].astype(cd)[None]
    x = shard(x, "batch", "seq", "embed")
    if positions is None:
        positions = jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32), (b, s))

    def body(xc, lp):
        h = xc + attn_apply(lp["self"], apply_norm(cfg, lp["ln1"], xc),
                            cfg, positions, causal=True)
        kv = cross_kv(lp["cross"], enc_out, cfg)
        h = h + _cross_attn(lp["cross"], apply_norm(cfg, lp["ln_x"], h),
                            kv, cfg)
        return h + mlp_apply(lp["mlp"], apply_norm(cfg, lp["ln2"], h),
                             cfg), None

    body = jax.checkpoint(body) if cfg.remat else body
    x, _ = jax.lax.scan(body, x, params["dec_layers"])
    return apply_norm(cfg, params["dec_norm"], x)


def loss_fn(params, batch, cfg: ArchConfig, impl: str = "auto"):
    """batch: frames [B,Se,d], tokens [B,S], labels [B,S]."""
    enc_out = encode(params, batch["frames"], cfg)
    h = decode_hidden(params, batch["tokens"], enc_out, cfg)
    w = params["embed"]["table"].T
    return chunked_softmax_xent(h, w, batch["labels"],
                                label_mask=batch.get("label_mask"))


def prefill(params, batch, cfg: ArchConfig, impl: str = "auto"):
    enc_out = encode(params, batch["frames"], cfg)
    h = decode_hidden(params, batch["tokens"], enc_out, cfg)
    last = h[:, -1, :]
    w = params["embed"]["table"].T
    return (last @ w.astype(last.dtype)).astype(jnp.float32)


# ---------------------------------------------------------------------------
# Decode with self-attn KV cache + precomputed cross KV
# ---------------------------------------------------------------------------

def init_cache(params, cfg: ArchConfig, batch: int, kv_len: int,
               enc_out=None, dtype=jnp.bfloat16):
    hkv, dh, L = cfg.n_kv_heads, cfg.d_head, cfg.n_layers
    cache: dict[str, Any] = {
        "k": jnp.zeros((L, batch, hkv, kv_len, dh), dtype),
        "v": jnp.zeros((L, batch, hkv, kv_len, dh), dtype),
    }
    if enc_out is None:
        enc_out = jnp.zeros((batch, cfg.enc_seq, cfg.d_model), dtype)
    # cross K/V computed once per request, layer-stacked
    def per_layer(lp):
        return cross_kv(lp["cross"], enc_out, cfg)
    cache["cross"] = jax.vmap(per_layer)(
        jax.tree.map(lambda a: a, params["dec_layers"]))
    return cache


def decode_step(params, cache, batch, cfg: ArchConfig):
    """batch: tokens [B,1], index scalar.  Returns (logits, cache)."""
    cd = dtype_of(cfg, "compute_dtype")
    index = batch["index"].astype(jnp.int32)
    x = embed(params["embed"], batch["tokens"][:, 0], cd)
    x = x + params["dec_pos"][index].astype(cd)[None]

    def body(xc, inputs):
        lp, kc, vc, xkv = inputs
        h, new_kv = _decode_attn_block(
            lp["self"], apply_norm(cfg, lp["ln1"], xc),
            {"k": kc, "v": vc}, cfg, index)
        h = xc + h
        hx = apply_norm(cfg, lp["ln_x"], h[:, None, :])
        h = h + _cross_attn(lp["cross"], hx, {"k": xkv["k"], "v": xkv["v"]},
                            cfg)[:, 0]
        h = h + mlp_apply(lp["mlp"], apply_norm(cfg, lp["ln2"],
                                                h[:, None, :]), cfg)[:, 0]
        return h, (new_kv["k"], new_kv["v"])

    x, (new_k, new_v) = jax.lax.scan(
        body, x, (params["dec_layers"], cache["k"], cache["v"],
                  cache["cross"]))
    new_cache = dict(cache)
    new_cache["k"] = new_k
    new_cache["v"] = new_v
    x = apply_norm(cfg, params["dec_norm"], x[:, None, :])[:, 0]
    w = params["embed"]["table"].T
    logits = (x @ w.astype(x.dtype)).astype(jnp.float32)
    return logits, new_cache
