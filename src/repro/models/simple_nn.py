"""The paper's own models: SimpleNN (121->2, s=242) and ComplexNN
(121->60->2, s=7380) for the fault-detection use case (paper §IV-A).

These are the models whose tensors the MPC protocols aggregate in the
paper's experiments; ``benchmarks/accuracy.py`` reproduces Table II with
them and ``benchmarks/protocols.py`` reproduces Figs. 15–16.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

N_FEATURES = 121
N_CLASSES = 2
HIDDEN = 60


def init_simple(key):
    k1, = jax.random.split(key, 1)
    s = 1.0 / np.sqrt(N_FEATURES)
    return {"w": jax.random.normal(k1, (N_FEATURES, N_CLASSES)) * s,
            "b": jnp.zeros((N_CLASSES,))}


def init_complex(key):
    k1, k2 = jax.random.split(key)
    return {
        "w1": jax.random.normal(k1, (N_FEATURES, HIDDEN)) / np.sqrt(N_FEATURES),
        "b1": jnp.zeros((HIDDEN,)),
        "w2": jax.random.normal(k2, (HIDDEN, N_CLASSES)) / np.sqrt(HIDDEN),
        "b2": jnp.zeros((N_CLASSES,)),
    }


def forward_simple(params, x):
    return x @ params["w"] + params["b"]


def forward_complex(params, x):
    h = jax.nn.relu(x @ params["w1"] + params["b1"])
    return h @ params["w2"] + params["b2"]


def param_size(params) -> int:
    return sum(int(np.prod(l.shape)) for l in jax.tree.leaves(params))


def nll_loss(logits, labels):
    logp = jax.nn.log_softmax(logits)
    return -jnp.mean(jnp.take_along_axis(logp, labels[:, None], 1))


def make_model(kind: str):
    if kind == "simple":
        return init_simple, forward_simple
    if kind == "complex":
        return init_complex, forward_complex
    raise ValueError(kind)
