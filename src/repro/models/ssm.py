"""Mamba-1 selective-SSM block (falcon-mamba-7b architecture).

Faithful Mamba-1 dataflow (in_proj -> causal depthwise conv -> selective
SSM -> gated out_proj) with the selective scan realized as a chunked
associative scan (see ``scan_utils``) — the TPU-native equivalent of the
fused CUDA kernel, per DESIGN.md's hardware-adaptation ledger.

Decode is O(1)/token: carries ``(conv_state [B, k-1, di],
ssm_state [B, di, n])`` — this is why falcon-mamba runs the ``long_500k``
cell that full-attention architectures skip.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from .common import ArchConfig, shard
from .scan_utils import chunked_linear_scan


def ssm_block_init(key, cfg: ArchConfig, dtype):
    d, di, st = cfg.d_model, cfg.d_inner, cfg.ssm_state
    dtr, kc = cfg.dt_rank, cfg.ssm_conv
    ks = jax.random.split(key, 6)
    si = 1.0 / np.sqrt(d)
    sdi = 1.0 / np.sqrt(di)
    sdt = 1.0 / np.sqrt(dtr)
    a_init = jnp.tile(jnp.arange(1, st + 1, dtype=jnp.float32)[None, :],
                      (di, 1))
    return {
        "in_proj": jax.random.normal(ks[0], (d, 2 * di), dtype) * si,
        "conv_w": jax.random.normal(ks[1], (kc, di), dtype) * 0.2,
        "conv_b": jnp.zeros((di,), dtype),
        "x_proj": jax.random.normal(ks[2], (di, dtr + 2 * st), dtype) * sdi,
        "dt_proj": jax.random.normal(ks[3], (dtr, di), dtype) * sdt,
        "dt_bias": jnp.full((di,), -4.6, dtype),   # softplus^-1(0.01)
        "A_log": jnp.log(a_init),
        "D": jnp.ones((di,), jnp.float32),
        "out_proj": jax.random.normal(ks[4], (di, d), dtype) * sdi,
    }


def _causal_conv(x, w, b):
    """x: [B,S,di]; w: [k,di] depthwise causal conv along S."""
    k = w.shape[0]
    xp = jnp.pad(x, ((0, 0), (k - 1, 0), (0, 0)))
    out = jnp.zeros_like(x)
    for i in range(k):
        out = out + xp[:, i:i + x.shape[1], :] * w[i][None, None, :]
    return out + b[None, None, :]


def ssm_block_apply(params, x, cfg: ArchConfig, chunk: int = 64):
    """x: [B, S, d] -> [B, S, d] (training / prefill path)."""
    cd = x.dtype
    di, st = cfg.d_inner, cfg.ssm_state
    xz = x @ params["in_proj"].astype(cd)
    x_in, z = jnp.split(xz, 2, axis=-1)
    x_in = shard(x_in, "batch", "seq", "ff")
    x_c = jax.nn.silu(_causal_conv(x_in, params["conv_w"].astype(cd),
                                   params["conv_b"].astype(cd)))

    dbc = x_c @ params["x_proj"].astype(cd)
    dt, bc, cc = jnp.split(dbc, [cfg.dt_rank, cfg.dt_rank + st], axis=-1)
    dt = jax.nn.softplus(
        (dt @ params["dt_proj"].astype(cd)).astype(jnp.float32)
        + params["dt_bias"].astype(jnp.float32))          # [B,S,di]
    a = -jnp.exp(params["A_log"])                          # [di,st] f32

    x_f = x_c.astype(jnp.float32)
    if jax.default_backend() == "tpu" and x_c.shape[1] % 128 == 0 \
            and x_c.shape[2] % 128 == 0:
        # fused Pallas path: state lives in VMEM, the [B,S,di,st]
        # tensor never reaches HBM (kernels/ssm_scan)
        from repro.kernels.ssm_scan import ssm_scan
        y = ssm_scan(x_c, dt.astype(jnp.float32), bc, cc, a)
        y = y.astype(jnp.float32)
    else:
        sdt = (jnp.bfloat16 if cfg.scan_dtype == "bfloat16"
               else jnp.float32)
        da = jnp.exp(dt[..., None] * a[None, None]).astype(sdt)
        dbx = ((dt * x_f)[..., None]
               * bc.astype(jnp.float32)[:, :, None, :]).astype(sdt)
        hs, _ = chunked_linear_scan(da, dbx, chunk=chunk)  # [B,S,di,st]
        y = jnp.einsum("bsdn,bsn->bsd", hs.astype(jnp.float32),
                       cc.astype(jnp.float32))
    y = y + x_f * params["D"][None, None, :]
    y = (y.astype(cd) * jax.nn.silu(z))
    return y @ params["out_proj"].astype(cd)


def ssm_init_state(cfg: ArchConfig, batch: int, dtype=jnp.float32):
    di, st, kc = cfg.d_inner, cfg.ssm_state, cfg.ssm_conv
    return {
        "conv": jnp.zeros((batch, kc - 1, di), dtype),
        "ssm": jnp.zeros((batch, di, st), jnp.float32),
    }


def ssm_block_step(params, x, state, cfg: ArchConfig):
    """Single-token decode.  x: [B, d] -> ([B, d], new state)."""
    cd = x.dtype
    st = cfg.ssm_state
    xz = x @ params["in_proj"].astype(cd)
    x_in, z = jnp.split(xz, 2, axis=-1)                    # [B, di]

    conv_buf = jnp.concatenate([state["conv"], x_in[:, None, :]], axis=1)
    w = params["conv_w"].astype(cd)                        # [k, di]
    x_c = jnp.einsum("bkd,kd->bd", conv_buf, w) + params["conv_b"].astype(cd)
    x_c = jax.nn.silu(x_c)

    dbc = x_c @ params["x_proj"].astype(cd)
    dt, bc, cc = jnp.split(dbc, [cfg.dt_rank, cfg.dt_rank + st], axis=-1)
    dt = jax.nn.softplus(
        (dt @ params["dt_proj"].astype(cd)).astype(jnp.float32)
        + params["dt_bias"].astype(jnp.float32))           # [B, di]
    a = -jnp.exp(params["A_log"])                          # [di, st]
    da = jnp.exp(dt[..., None] * a[None])                  # [B, di, st]
    x_f = x_c.astype(jnp.float32)
    dbx = (dt * x_f)[..., None] * bc.astype(jnp.float32)[:, None, :]
    h = da * state["ssm"] + dbx                            # [B, di, st]
    y = jnp.einsum("bdn,bn->bd", h, cc.astype(jnp.float32))
    y = y + x_f * params["D"][None, :]
    y = (y.astype(cd) * jax.nn.silu(z)) @ params["out_proj"].astype(cd)
    new_state = {"conv": conv_buf[:, 1:], "ssm": h}
    return y, new_state
