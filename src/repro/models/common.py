"""Shared model config + logical-axis sharding annotations.

One ``ArchConfig`` covers every assigned architecture; family-specific
fields are simply unused elsewhere.  Models annotate *activations* with
logical axes via ``shard()``; the launch layer installs a logical→mesh
rule table (``sharding_rules`` context) so the same model code runs
unsharded in smoke tests and GSPMD-sharded in the dry-run/production
path.  Parameter shardings are decided by ``launch/sharding.py`` from
the pytree structure, not inside model code.
"""

from __future__ import annotations

import contextlib
import dataclasses
import threading
from typing import Any

import jax
from jax.sharding import PartitionSpec as P


# ---------------------------------------------------------------------------
# Architecture config
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class ArchConfig:
    name: str
    family: str                 # dense | moe | ssm | hybrid | audio | vlm
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_head: int
    d_ff: int
    vocab: int
    # --- attention ---
    rope: str = "rope"           # rope | mrope | none | sinusoidal
    rope_theta: float = 1e4
    qkv_bias: bool = False
    window: int = 0              # sliding-window size; 0 = full attention
    # --- MoE ---
    n_experts: int = 0
    top_k: int = 0
    d_ff_expert: int = 0
    capacity_factor: float = 1.25
    # --- SSM (mamba1) ---
    ssm_state: int = 0
    ssm_conv: int = 4
    ssm_expand: int = 2
    dt_rank: int = 0
    # --- hybrid (RG-LRU / Griffin) ---
    block_pattern: tuple[str, ...] = ()   # e.g. ("rec", "rec", "attn")
    lru_width: int = 0
    # --- enc-dec (whisper) ---
    enc_dec: bool = False
    n_enc_layers: int = 0
    enc_seq: int = 0              # encoder frames (1500 for whisper)
    # --- frontend ---
    frontend: str = "tokens"      # tokens | embeddings (audio/vlm stubs)
    # --- misc ---
    act: str = "silu"             # silu | gelu | geglu
    norm: str = "rmsnorm"         # rmsnorm | layernorm
    norm_eps: float = 1e-6
    tie_embeddings: bool = False
    param_dtype: Any = "float32"
    compute_dtype: Any = "bfloat16"
    remat: bool = True
    #: "full" rematerializes everything; "dots" saves matmul outputs
    #: (less recompute FLOPs, more activation memory) — §Perf knob.
    remat_policy: str = "full"
    #: MoE position-in-expert: "cumsum" ([T,E] scans) or "sort"
    #: (argsort over [T·k] keys — far less HBM traffic) — §Perf knob.
    moe_dispatch: str = "cumsum"
    #: storage dtype of the SSM/LRU scan tree ("float32" | "bfloat16") —
    #: bf16 halves the dominant HBM term of recurrent archs (§Perf).
    scan_dtype: str = "float32"
    # how many trailing layers fall outside the scanned homogeneous stack
    # (RecurrentGemma's 38 = 12×(rec,rec,attn) + 2×rec)
    n_tail_layers: int = 0

    @property
    def group(self) -> int:
        return self.n_heads // self.n_kv_heads

    @property
    def d_inner(self) -> int:
        return self.ssm_expand * self.d_model

    def param_count(self) -> int:
        """Analytic parameter count (for MODEL_FLOPS roofline term)."""
        d, v = self.d_model, self.vocab
        emb = v * d * (1 if self.tie_embeddings else 2)
        if self.family == "ssm":
            di, st, dtr = self.d_inner, self.ssm_state, self.dt_rank
            per = (d * 2 * di + di * self.ssm_conv
                   + di * (dtr + 2 * st) + dtr * di + 2 * di + di * d
                   + d)
            return emb + self.n_layers * per
        qk = d * self.n_heads * self.d_head + d * self.n_kv_heads * self.d_head * 2
        op = self.n_heads * self.d_head * d
        attn = qk + op
        if self.family == "hybrid":
            w = self.lru_width or d
            rec = (2 * d * w + 4 * w + (2 * w * w + 3 * w) + w * d
                   + 3 * d * self.d_ff + 2 * d)
            pattern = self.block_pattern or ("rec", "rec", "attn")
            n_groups = (self.n_layers - self.n_tail_layers) // len(pattern)
            n_rec = (n_groups * sum(1 for k in pattern if k == "rec")
                     + self.n_tail_layers)
            n_att = n_groups * sum(1 for k in pattern if k == "attn")
            att_layer = attn + 3 * d * self.d_ff + 2 * d
            return emb + n_rec * rec + n_att * att_layer
        if self.n_experts:
            ff = self.n_experts * 3 * d * self.d_ff_expert + d * self.n_experts
        else:
            mult = 3 if self.act in ("silu", "geglu") else 2
            ff = mult * d * self.d_ff
        per = attn + ff + 2 * d
        total = emb + self.n_layers * per
        if self.enc_dec:
            enc_per = attn + (2 * d * self.d_ff) + 2 * d
            cross = attn
            total += self.n_enc_layers * enc_per + self.n_layers * cross
        return total

    def active_param_count(self) -> int:
        """Params touched per token (MoE: top_k of n_experts)."""
        if not self.n_experts:
            return self.param_count()
        d = self.d_model
        full = self.param_count()
        all_experts = self.n_layers * self.n_experts * 3 * d * self.d_ff_expert
        active = self.n_layers * self.top_k * 3 * d * self.d_ff_expert
        return full - all_experts + active


# ---------------------------------------------------------------------------
# Logical-axis sharding annotations
# ---------------------------------------------------------------------------

_LOCAL = threading.local()

#: Production rule table: logical activation axis -> mesh axis (or None).
DEFAULT_RULES: dict[str, Any] = {
    "batch": ("pod", "data"),
    "seq": None,
    "embed": None,
    "heads": "model",
    "kv_heads": None,
    "kv_seq": "model",     # decode KV caches are sequence-sharded
    "ff": "model",
    "experts": None,
    "expert_ff": "model",
    "vocab": "model",
    "qseq": "model",       # prefill SP fallback when heads don't divide
}


@contextlib.contextmanager
def sharding_rules(rules: dict[str, Any] | None):
    prev = getattr(_LOCAL, "rules", None)
    _LOCAL.rules = rules
    try:
        yield
    finally:
        _LOCAL.rules = prev


def current_rules() -> dict[str, Any] | None:
    return getattr(_LOCAL, "rules", None)


def shard(x, *logical_axes: str | None):
    """Annotate ``x`` with logical axes; no-op outside a rules context.

    ``logical_axes`` has one entry per dimension of ``x`` (None = do not
    constrain that dim).  Dims whose size does not divide the assigned
    mesh-axis extent are left unconstrained (e.g. batch=1 long-context
    decode), and a mesh axis is never used twice in one spec.
    """
    rules = current_rules()
    if rules is None:
        return x
    try:
        mesh = jax.sharding.get_abstract_mesh()
        sizes = dict(zip(mesh.axis_names, mesh.axis_sizes)) \
            if mesh is not None and mesh.axis_names else {}
    except Exception:
        sizes = {}

    used: set = set()
    spec = []
    for i, ax in enumerate(logical_axes):
        entry = rules.get(ax) if ax is not None else None
        if entry is not None:
            axes = entry if isinstance(entry, tuple) else (entry,)
            div = 1
            ok = True
            for a in axes:
                if a in used or (sizes and a not in sizes):
                    ok = False
                    break
                div *= sizes.get(a, 1)
            if ok and sizes and x.shape[i] % max(div, 1) != 0:
                ok = False
            if not ok:
                entry = None
            else:
                used.update(axes)
        spec.append(entry)
    return jax.lax.with_sharding_constraint(x, P(*spec))


def dtype_of(cfg: ArchConfig, which: str):
    import jax.numpy as jnp
    name = getattr(cfg, which)
    return {"float32": jnp.float32, "bfloat16": jnp.bfloat16,
            "float16": jnp.float16}[str(name)]
