"""Chunked first-order linear recurrence: ``h_t = a_t·h_{t-1} + b_t``.

The workhorse of both Mamba-1 and RG-LRU.  TPU adaptation of the CUDA
"selective scan": instead of a hand-written warp scan we use
``jax.lax.associative_scan`` (log-depth, maps onto VPU shuffles) inside
fixed-size chunks, with a sequential ``lax.scan`` carrying state across
chunks.  The chunk size bounds the materialized ``[B, chunk, ...state]``
intermediates — for falcon-mamba (d_inner 8192 × state 16) an unchunked
scan would need ~17 GB/device at train_4k; chunk=64 keeps it <100 MB.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp


def _combine(c1, c2):
    a1, b1 = c1
    a2, b2 = c2
    return a2 * a1, a2 * b1 + b2


def chunked_linear_scan(a, b, h0=None, chunk: int = 64):
    """a, b: [B, S, ...]; h0: [B, ...] initial state (zeros if None).

    Returns (h: [B, S, ...] all states, h_last: [B, ...]).
    """
    bsz, s = a.shape[0], a.shape[1]
    rest = a.shape[2:]
    chunk = min(chunk, s)
    assert s % chunk == 0, (s, chunk)
    n_chunks = s // chunk
    if h0 is None:
        h0 = jnp.zeros((bsz,) + rest, a.dtype)

    ac = a.reshape((bsz, n_chunks, chunk) + rest).swapaxes(0, 1)
    bc = b.reshape((bsz, n_chunks, chunk) + rest).swapaxes(0, 1)

    def outer(h_carry, inputs):
        a_ch, b_ch = inputs                     # [B, chunk, ...]
        # fold carry into the first step: h_1 = a_1·h0 + b_1
        b_ch = b_ch.at[:, 0].add(a_ch[:, 0] * h_carry)
        aa, hh = jax.lax.associative_scan(_combine, (a_ch, b_ch), axis=1)
        return hh[:, -1], hh

    h_last, hs = jax.lax.scan(outer, h0, (ac, bc))
    hs = hs.swapaxes(0, 1).reshape((bsz, s) + rest)
    return hs, h_last
