"""Architecture registry: ``--arch <id>`` -> config module."""

from __future__ import annotations

import importlib

from repro.models.common import ArchConfig
from .base import (SHAPES, ShapeCell, decode_kv_len, input_specs,
                   skip_reason, valid_shapes)

_MODULES = {
    "qwen3-moe-235b-a22b": "qwen3_moe_235b",
    "grok-1-314b": "grok1_314b",
    "llama3.2-1b": "llama32_1b",
    "tinyllama-1.1b": "tinyllama_11b",
    "starcoder2-3b": "starcoder2_3b",
    "qwen1.5-0.5b": "qwen15_05b",
    "falcon-mamba-7b": "falcon_mamba_7b",
    "recurrentgemma-9b": "recurrentgemma_9b",
    "whisper-large-v3": "whisper_large_v3",
    "qwen2-vl-2b": "qwen2_vl_2b",
}

ARCH_NAMES = tuple(_MODULES)


def get_config(name: str, smoke: bool = False) -> ArchConfig:
    if name not in _MODULES:
        raise KeyError(f"unknown arch {name!r}; choose from {ARCH_NAMES}")
    mod = importlib.import_module(f"repro.configs.{_MODULES[name]}")
    return mod.SMOKE if smoke else mod.ARCH


__all__ = ["ARCH_NAMES", "get_config", "input_specs", "valid_shapes",
           "skip_reason", "SHAPES", "ShapeCell", "decode_kv_len"]
