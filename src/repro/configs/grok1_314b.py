"""grok-1-314b — 64L d6144 48H (GQA kv=8) d_ff=32768, MoE 8e top-2.

[hf:xai-org/grok-1; unverified]
"""
import dataclasses
from repro.models.common import ArchConfig

ARCH = ArchConfig(
    name="grok-1-314b", family="moe",
    n_layers=64, d_model=6144, n_heads=48, n_kv_heads=8, d_head=128,
    d_ff=32768, vocab=131072,
    n_experts=8, top_k=2, d_ff_expert=32768,
    rope="rope", rope_theta=1e4,
)

SMOKE = dataclasses.replace(
    ARCH, n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, d_head=16,
    d_ff=64, vocab=256, n_experts=4, top_k=2, d_ff_expert=64, remat=False)
