"""qwen2-vl-2b — 28L d1536 12H (GQA kv=2) d_ff=8960 vocab=151936.

[arXiv:2409.12191; hf] — M-RoPE (3-section rotary over t/h/w position
streams; text streams coincide), dynamic-resolution vision frontend
STUB: input_specs provides precomputed patch/text embeddings
[B, S, 1536].  QKV bias, tied embeddings (2B).
"""
import dataclasses
from repro.models.common import ArchConfig

ARCH = ArchConfig(
    name="qwen2-vl-2b", family="vlm",
    n_layers=28, d_model=1536, n_heads=12, n_kv_heads=2, d_head=128,
    d_ff=8960, vocab=151936,
    rope="mrope", rope_theta=1e6, qkv_bias=True, tie_embeddings=True,
    frontend="embeddings",
)

SMOKE = dataclasses.replace(
    ARCH, n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, d_head=16,
    d_ff=128, vocab=256, remat=False)
