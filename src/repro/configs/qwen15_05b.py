"""qwen1.5-0.5b — 24L d1024 16H (kv=16, MHA) d_ff=2816 vocab=151936.

[hf:Qwen/Qwen1.5-0.5B; hf] — QKV bias, tied embeddings.
"""
import dataclasses
from repro.models.common import ArchConfig

ARCH = ArchConfig(
    name="qwen1.5-0.5b", family="dense",
    n_layers=24, d_model=1024, n_heads=16, n_kv_heads=16, d_head=64,
    d_ff=2816, vocab=151936,
    rope="rope", rope_theta=1e4, qkv_bias=True, tie_embeddings=True,
)

SMOKE = dataclasses.replace(
    ARCH, n_layers=2, d_model=64, n_heads=4, n_kv_heads=4, d_head=16,
    d_ff=128, vocab=256, remat=False)
