"""Shape cells + input specs shared by every architecture config.

Each architecture is paired with four input-shape cells:

  train_4k    — seq 4096,   global_batch 256  (lowers ``train_step``)
  prefill_32k — seq 32768,  global_batch 32   (lowers ``prefill``)
  decode_32k  — KV 32768,   global_batch 128  (lowers ``serve_step``)
  long_500k   — KV 524288,  global_batch 1    (serve_step; sub-quadratic
                                               architectures only)

``input_specs`` returns global-shape ``ShapeDtypeStruct`` stand-ins (no
allocation) for everything the step function consumes besides params;
``valid_shapes`` encodes the per-family skips documented in DESIGN.md
§Arch-applicability (full-attention archs skip long_500k).
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from repro.models.common import ArchConfig


@dataclasses.dataclass(frozen=True)
class ShapeCell:
    name: str
    kind: str          # train | prefill | decode
    seq: int
    global_batch: int


SHAPES: dict[str, ShapeCell] = {
    "train_4k": ShapeCell("train_4k", "train", 4096, 256),
    "prefill_32k": ShapeCell("prefill_32k", "prefill", 32768, 32),
    "decode_32k": ShapeCell("decode_32k", "decode", 32768, 128),
    "long_500k": ShapeCell("long_500k", "decode", 524288, 1),
}

_SUBQUADRATIC = ("ssm", "hybrid")


def valid_shapes(cfg: ArchConfig) -> list[str]:
    """Cells this architecture runs (skips per DESIGN.md)."""
    cells = ["train_4k", "prefill_32k", "decode_32k"]
    if cfg.family in _SUBQUADRATIC:
        cells.append("long_500k")
    return cells


def skip_reason(cfg: ArchConfig, shape: str) -> str | None:
    if shape in valid_shapes(cfg):
        return None
    if shape == "long_500k":
        return ("full quadratic attention: 512k-token decode KV/compute "
                "infeasible by design; sub-quadratic archs only "
                "(DESIGN.md §3)")
    return "not applicable"


def _i32(*shape):
    return jax.ShapeDtypeStruct(shape, jnp.int32)


def _bf16(*shape):
    return jax.ShapeDtypeStruct(shape, jnp.bfloat16)


def input_specs(cfg: ArchConfig, shape_name: str,
                batch_override: int | None = None) -> dict:
    """Global-shape input stand-ins for (arch × shape)."""
    cell = SHAPES[shape_name]
    b = batch_override or cell.global_batch
    s = cell.seq

    if cell.kind in ("train", "prefill"):
        if cfg.enc_dec:
            batch = {"frames": _bf16(b, cfg.enc_seq, cfg.d_model),
                     "tokens": _i32(b, s)}
        elif cfg.frontend == "embeddings":
            batch = {"embeds": _bf16(b, s, cfg.d_model)}
        else:
            batch = {"tokens": _i32(b, s)}
        if cell.kind == "train":
            batch["labels"] = _i32(b, s)
        return batch

    # decode: one new token against a kv_len cache
    batch = {"tokens": _i32(b, 1), "index": jax.ShapeDtypeStruct((), jnp.int32)}
    if cfg.frontend == "embeddings":
        batch = {"embeds": _bf16(b, 1, cfg.d_model),
                 "index": jax.ShapeDtypeStruct((), jnp.int32)}
    return batch


def decode_kv_len(shape_name: str) -> int:
    return SHAPES[shape_name].seq
