"""llama3.2-1b — 16L d2048 32H (GQA kv=8) d_ff=8192 vocab=128256.

[hf:meta-llama/Llama-3.2-1B; unverified] — tied embeddings, rope 5e5.
"""
import dataclasses
from repro.models.common import ArchConfig

ARCH = ArchConfig(
    name="llama3.2-1b", family="dense",
    n_layers=16, d_model=2048, n_heads=32, n_kv_heads=8, d_head=64,
    d_ff=8192, vocab=128256,
    rope="rope", rope_theta=5e5, tie_embeddings=True,
)

SMOKE = dataclasses.replace(
    ARCH, n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, d_head=16,
    d_ff=128, vocab=256, remat=False)
