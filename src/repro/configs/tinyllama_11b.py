"""tinyllama-1.1b — 22L d2048 32H (GQA kv=4) d_ff=5632 vocab=32000.

[arXiv:2401.02385; hf] — llama2-arch small.
"""
import dataclasses
from repro.models.common import ArchConfig

ARCH = ArchConfig(
    name="tinyllama-1.1b", family="dense",
    n_layers=22, d_model=2048, n_heads=32, n_kv_heads=4, d_head=64,
    d_ff=5632, vocab=32000,
    rope="rope", rope_theta=1e4,
)

SMOKE = dataclasses.replace(
    ARCH, n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, d_head=16,
    d_ff=128, vocab=256, remat=False)
