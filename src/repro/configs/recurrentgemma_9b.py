"""recurrentgemma-9b — 38L d4096 16H (MQA kv=1) d_ff=12288.

[arXiv:2402.19427; unverified] — Griffin: repeating (rec, rec,
local-attn) triads (12 triads + 2 tail recurrent layers), RG-LRU width
4096, local attention window 2048, GeGLU, vocab 256000.
Runs long_500k (ring-buffer window cache + O(1) recurrent state).
"""
import dataclasses
from repro.models.common import ArchConfig

ARCH = ArchConfig(
    name="recurrentgemma-9b", family="hybrid",
    n_layers=38, d_model=4096, n_heads=16, n_kv_heads=1, d_head=256,
    d_ff=12288, vocab=256000,
    block_pattern=("rec", "rec", "attn"), n_tail_layers=2,
    lru_width=4096, window=2048,
    rope="rope", rope_theta=1e4, act="geglu",
)

SMOKE = dataclasses.replace(
    ARCH, n_layers=4, n_tail_layers=1, d_model=64, n_heads=4,
    n_kv_heads=1, d_head=16, d_ff=128, vocab=256, lru_width=64,
    window=8, remat=False)
