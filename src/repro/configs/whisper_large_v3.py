"""whisper-large-v3 — enc-dec, 32+32L d1280 20H (MHA) d_ff=5120.

[arXiv:2212.04356; unverified] — conv frontend STUB: input_specs
provides post-conv frame embeddings [B, 1500, 1280].  LayerNorm + GELU,
sinusoidal encoder positions, learned decoder positions, tied decoder
embedding, vocab 51866.
"""
import dataclasses
from repro.models.common import ArchConfig

ARCH = ArchConfig(
    name="whisper-large-v3", family="audio",
    n_layers=32, d_model=1280, n_heads=20, n_kv_heads=20, d_head=64,
    d_ff=5120, vocab=51866,
    enc_dec=True, n_enc_layers=32, enc_seq=1500,
    rope="none", act="gelu", norm="layernorm", norm_eps=1e-5,
    tie_embeddings=True, frontend="frames",
)

SMOKE = dataclasses.replace(
    ARCH, n_layers=2, n_enc_layers=2, d_model=64, n_heads=4,
    n_kv_heads=4, d_head=16, d_ff=128, vocab=256, enc_seq=16,
    remat=False)
