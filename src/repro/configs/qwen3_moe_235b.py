"""qwen3-moe-235b-a22b — 94L d4096 64H (GQA kv=4) MoE 128e top-8.

[hf:Qwen/Qwen3-235B-A22B family; assignment spec verbatim]
Expert FF width 1536 (the assignment's d_ff), vocab 151936.
"""
import dataclasses
from repro.models.common import ArchConfig

ARCH = ArchConfig(
    name="qwen3-moe-235b-a22b", family="moe",
    n_layers=94, d_model=4096, n_heads=64, n_kv_heads=4, d_head=128,
    d_ff=1536, vocab=151936,
    n_experts=128, top_k=8, d_ff_expert=1536,
    rope="rope", rope_theta=1e6,
)

SMOKE = dataclasses.replace(
    ARCH, n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, d_head=16,
    d_ff=32, vocab=256, n_experts=4, top_k=2, d_ff_expert=32, remat=False)
