"""starcoder2-3b — 30L d3072 24H (GQA kv=2) d_ff=12288 vocab=49152.

[arXiv:2402.19173; hf] — GQA + RoPE, LayerNorm, gelu MLP, qkv bias,
sliding window 4096.
"""
import dataclasses
from repro.models.common import ArchConfig

ARCH = ArchConfig(
    name="starcoder2-3b", family="dense",
    n_layers=30, d_model=3072, n_heads=24, n_kv_heads=2, d_head=128,
    d_ff=12288, vocab=49152,
    rope="rope", rope_theta=1e6, qkv_bias=True,
    act="gelu", norm="layernorm", norm_eps=1e-5, window=4096,
)

SMOKE = dataclasses.replace(
    ARCH, n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, d_head=16,
    d_ff=128, vocab=256, window=8, remat=False)
