"""falcon-mamba-7b — 64L d4096 attn-free mamba1, ssm_state=16.

[arXiv:2410.05355; unverified] — d_inner = 2·d = 8192, conv 4,
dt_rank = d/16 = 256, vocab 65024.  Runs long_500k (O(1) state decode).
"""
import dataclasses
from repro.models.common import ArchConfig

ARCH = ArchConfig(
    name="falcon-mamba-7b", family="ssm",
    n_layers=64, d_model=4096, n_heads=1, n_kv_heads=1, d_head=1,
    d_ff=0, vocab=65024,
    ssm_state=16, ssm_conv=4, ssm_expand=2, dt_rank=256,
    rope="none",
)

SMOKE = dataclasses.replace(
    ARCH, n_layers=2, d_model=64, vocab=256, ssm_state=4, dt_rank=8,
    remat=False)
