from .adamw import (AdamWConfig, SGDConfig, adamw_init, adamw_update,
                    clip_by_global_norm, global_norm, sgd_init, sgd_update)
from .schedule import inverse_sqrt, warmup_cosine

__all__ = ["AdamWConfig", "SGDConfig", "adamw_init", "adamw_update",
           "sgd_init", "sgd_update", "clip_by_global_norm", "global_norm",
           "warmup_cosine", "inverse_sqrt"]
