"""AdamW + SGD(+momentum) implemented from scratch (no optax installed).

Functional optimizers over pytrees: ``init(params) -> state``;
``update(grads, state, params, step) -> (new_params, new_state)``.
fp32 moments regardless of param dtype (mixed-precision master math).
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.01
    grad_clip: float = 1.0


def global_norm(tree) -> jnp.ndarray:
    leaves = [jnp.sum(jnp.square(l.astype(jnp.float32)))
              for l in jax.tree.leaves(tree)]
    return jnp.sqrt(jnp.sum(jnp.stack(leaves)))


def clip_by_global_norm(grads, max_norm: float):
    norm = global_norm(grads)
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(norm, 1e-12))
    return jax.tree.map(lambda g: (g.astype(jnp.float32) * scale), grads), norm


def adamw_init(params):
    zeros = lambda p: jnp.zeros(p.shape, jnp.float32)
    return {"m": jax.tree.map(zeros, params),
            "v": jax.tree.map(zeros, params)}


def adamw_update(grads, state, params, step, cfg: AdamWConfig):
    grads, _ = clip_by_global_norm(grads, cfg.grad_clip)
    t = (step + 1).astype(jnp.float32)
    bc1 = 1.0 - cfg.b1 ** t
    bc2 = 1.0 - cfg.b2 ** t

    def upd(g, m, v, p):
        g = g.astype(jnp.float32)
        pf = p.astype(jnp.float32)
        m2 = cfg.b1 * m + (1 - cfg.b1) * g
        v2 = cfg.b2 * v + (1 - cfg.b2) * g * g
        update = (m2 / bc1) / (jnp.sqrt(v2 / bc2) + cfg.eps)
        pf = pf - cfg.lr * (update + cfg.weight_decay * pf)
        return pf.astype(p.dtype), m2, v2

    flat_p, treedef = jax.tree.flatten(params)
    flat_g = treedef.flatten_up_to(grads)
    flat_m = treedef.flatten_up_to(state["m"])
    flat_v = treedef.flatten_up_to(state["v"])
    out = [upd(g, m, v, p) for g, m, v, p in
           zip(flat_g, flat_m, flat_v, flat_p)]
    new_p = treedef.unflatten([o[0] for o in out])
    new_m = treedef.unflatten([o[1] for o in out])
    new_v = treedef.unflatten([o[2] for o in out])
    return new_p, {"m": new_m, "v": new_v}


@dataclasses.dataclass(frozen=True)
class SGDConfig:
    lr: float = 0.05
    momentum: float = 0.0


def sgd_init(params):
    return {"mu": jax.tree.map(
        lambda p: jnp.zeros(p.shape, jnp.float32), params)}


def sgd_update(grads, state, params, step, cfg: SGDConfig):
    del step

    def upd(g, mu, p):
        g = g.astype(jnp.float32)
        mu2 = cfg.momentum * mu + g
        return (p.astype(jnp.float32) - cfg.lr * mu2).astype(p.dtype), mu2

    flat_p, treedef = jax.tree.flatten(params)
    flat_g = treedef.flatten_up_to(grads)
    flat_mu = treedef.flatten_up_to(state["mu"])
    out = [upd(g, mu, p) for g, mu, p in zip(flat_g, flat_mu, flat_p)]
    return (treedef.unflatten([o[0] for o in out]),
            {"mu": treedef.unflatten([o[1] for o in out])})
